"""Compiled estimation sessions: one :class:`Plan` -> three verbs.

An :class:`EstimationSession` is a plan *compiled*: the graph's degree
buckets, owner structure, per-node block layouts, and fixed-coordinate
vectors are derived once; the jitted degree-bucket Newton solvers are
keyed by the plan's static configuration (family, singleton policy, Newton
budget, mesh, influence demand), so every verb — and every subsequent call
of the same verb — reuses the same compiled programs. Sessions themselves
are cached per plan (``EstimationSession.for_plan`` / ``plan.session()``):
two equal plans share one session and therefore one solver cache.

The four verbs share that cache:

* ``session.fit(X)``     — batch: per-node local CL fits + every requested
                           one-step combiner;
* ``session.stream()``   — a :class:`StreamingEstimator` bound to the plan
                           (same family, mesh, buffer, Newton budget — its
                           incremental re-fits hit the same solvers);
* ``session.joint(X)``   — ADMM joint MPLE through the batched proximal
                           engine;
* ``session.select(X)``  — structure learning: distributed
                           pseudo-likelihood lasso over candidate edges +
                           support voting (:mod:`repro.structure`),
                           returning a :class:`~repro.structure.
                           StructureResult`.

Each returns (or feeds) a structured :class:`~repro.api.result.
EstimateResult` with wall/compile counters and communication-cost scalars.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.admm import admm_mple_family
from ..core.asymptotics import free_indices, param_owners
from ..core.batched import (bucket_compile_count, degree_buckets,
                            fit_all_local_batched, prox_compile_count)
from ..core.estimators import LocalFit
from ..core.graphs import Graph
from ..telemetry.recorder import make_recorder
from .plan import Plan
from .result import EstimateResult

#: session cache — equal plans (and mesh override) share one compiled
#: session; bounded FIFO so long-lived processes cannot leak sessions
_SESSIONS: Dict[Tuple[Plan, object], "EstimationSession"] = {}
_SESSION_CACHE_MAX = 64


def _resolve_mesh(policy: Optional[str]):
    """Materialize a plan's mesh policy into a jax Mesh (or None)."""
    if policy is None:
        return None
    if policy == "host":
        from ..launch.mesh import make_host_mesh
        return make_host_mesh()
    if policy == "data":
        return jax.make_mesh((len(jax.devices()),), ("data",))
    raise ValueError(f"unknown mesh policy {policy!r}")


class EstimationSession:
    """A compiled :class:`Plan`; see the module docstring.

    Construct through :meth:`for_plan` (or ``plan.session()``) to share
    sessions between equal plans. ``mesh`` overrides the plan's mesh
    *policy* with a concrete ``jax.sharding.Mesh`` (used by the legacy
    shims, which receive mesh objects directly).
    """

    def __init__(self, plan: Plan, mesh=None) -> None:
        self.plan = plan
        self.graph: Graph = plan.graph
        self.family = plan.family_instance
        self.combiners = plan.combiner_instances
        self.mesh = mesh if mesh is not None else _resolve_mesh(plan.mesh)
        self.dtype = jnp.dtype(plan.precision)

        # ---- compile-once plan derivations --------------------------------
        self.buckets = degree_buckets(plan.graph)
        self.owners = param_owners(plan.graph, plan.include_singleton,
                                   self.family)
        self.free = np.asarray(free_indices(plan.graph,
                                            plan.include_singleton,
                                            self.family))
        self.betas = [self.family.beta(plan.graph, i, plan.include_singleton)
                      for i in range(plan.graph.p)]
        n_params = self.family.n_params(plan.graph)
        self.theta_fixed = (np.zeros(n_params, dtype=np.float64)
                            if plan.theta_fixed is None
                            else np.asarray(plan.theta_fixed,
                                            dtype=np.float64))
        #: union of the requested combiners' second-order demands
        self.needs = frozenset().union(*(c.needs for c in self.combiners))
        self.want_influence = "influence" in self.needs
        #: owner slots of shared (multi-owner) parameters — the unit the
        #: communication accounting bills per scheme
        self.shared_owner_slots = sum(
            len(own) for own in self.owners.values() if len(own) > 1)
        self.fit_calls = 0
        #: the plan's telemetry recorder — the shared allocation-free
        #: NULL_RECORDER unless the plan declares a TelemetrySpec; one
        #: long-lived recorder per session, scoped per verb call via
        #: mark()/snapshot()
        self.recorder = make_recorder(plan.telemetry)

    # ----------------------------------------------------------- caching
    @classmethod
    def for_plan(cls, plan: Plan, mesh=None) -> "EstimationSession":
        """The cached session for ``plan`` (creating it on first use).

        Equal plans hash to the same key, so they share one session — and
        with it the derived bucket/owner structures and the jitted solver
        cache entries its verbs have already populated.
        """
        key = (plan, mesh)
        sess = _SESSIONS.get(key)
        if sess is None:
            if len(_SESSIONS) >= _SESSION_CACHE_MAX:
                _SESSIONS.pop(next(iter(_SESSIONS)))
            sess = cls(plan, mesh=mesh)
            _SESSIONS[key] = sess
        return sess

    @property
    def n_buckets(self) -> int:
        """Degree buckets == compiled solver programs per fit variant."""
        return len(self.buckets)

    # ------------------------------------------------------------ helpers
    def _as_samples(self, X) -> jnp.ndarray:
        Xj = jnp.asarray(X, dtype=self.dtype)
        if Xj.dtype != self.dtype:
            # jax silently truncates float64 to float32 when x64 is off —
            # a plan that declares a precision must get it or fail loudly
            raise ValueError(
                f"plan declares precision={self.plan.precision!r} but jax "
                f"produced {Xj.dtype} (enable x64 via JAX_ENABLE_X64=1 or "
                f"jax.config.update('jax_enable_x64', True) to honor "
                f"float64 plans)")
        return Xj

    def _tf(self, dtype) -> jnp.ndarray:
        return jnp.asarray(self.theta_fixed, dtype=dtype)

    def _score_norm(self, theta: np.ndarray, X, n: int) -> float:
        from ..stream.online import pseudo_score
        g = pseudo_score(self.graph, theta, X, n, family=self.family)
        return float(np.linalg.norm(g))

    def one_step_comm(self, n: int) -> Dict[str, int]:
        """Scalars a network transmits per requested scheme — the
        family-block generalization of :mod:`repro.stream.costs`, with the
        per-param message size read from the combiner registry (the single
        source ``Combiner.scalars_per_shared_param``): every owner of every
        shared param ships its estimate (+ weight when the scheme uses
        one); Linear-Opt additionally ships its n influence samples per
        shared slot. The serving tier bills per-tenant comm budgets with
        exactly this accounting (summed over schemes)."""
        from ..stream.costs import one_step_comm_by_scheme
        return one_step_comm_by_scheme(self.shared_owner_slots,
                                       self.plan.combiners, n)

    # backward-compatible private alias
    _one_step_comm = one_step_comm

    def fit_local(self, X, sample_weight=None, warm_start=None,
                  want_influence: Optional[bool] = None,
                  theta_fixed=None, stats=None) -> List[LocalFit]:
        """Per-node local CL fits under this plan (the raw engine call the
        legacy ``fit_all_local`` shim routes through).

        ``theta_fixed`` overrides the plan's fixed coordinates for this
        call only — the shim passes per-call arrays here so a caller
        varying them does not mint a new plan (and session cache entry)
        per value.
        """
        Xj = self._as_samples(X)
        tf = (self._tf(Xj.dtype) if theta_fixed is None
              else jnp.asarray(theta_fixed, Xj.dtype))
        return fit_all_local_batched(
            self.graph, Xj,
            include_singleton=self.plan.include_singleton,
            theta_fixed=tf, n_iter=self.plan.n_iter,
            sample_weight=sample_weight, warm_start=warm_start,
            family=self.family, mesh=self.mesh,
            want_influence=(self.want_influence if want_influence is None
                            else want_influence),
            recorder=self.recorder, stats=stats)

    # -------------------------------------------------------------- verbs
    def fit(self, X, sample_weight=None, warm_start=None) -> EstimateResult:
        """Batch verb: local fits + every requested combiner.

        A warm session re-fit on fresh same-shape data triggers zero new
        solver compilations (the bench's ``session_reuse`` row and
        ``tests/api`` assert this).
        """
        rec = self.recorder
        mark = rec.mark()
        t0 = time.perf_counter()
        c0 = bucket_compile_count()
        stats = {"compile_s": 0.0}
        with rec.span("fit"):
            Xj = self._as_samples(X)
            n = int(Xj.shape[0])
            fits = self.fit_local(Xj, sample_weight=sample_weight,
                                  warm_start=warm_start, stats=stats)

            def _combine_one(c):
                return c.combine(
                    self.graph, fits,
                    include_singleton=self.plan.include_singleton,
                    theta_fixed=self.theta_fixed, family=self.family)

            combined = {}
            for c in self.combiners:
                if rec.enabled:
                    with rec.span("combine", scheme=c.name):
                        combined[c.name] = _combine_one(c)
                else:
                    combined[c.name] = _combine_one(c)
            theta = combined[self.plan.combiners[0]]
            score = self._score_norm(theta, Xj, n)
        c1 = bucket_compile_count()
        self.fit_calls += 1
        comm = self._one_step_comm(n)
        if rec.enabled:
            for scheme, cost in comm.items():
                rec.gauge("comm.scalars_per_round", cost, scheme=scheme)
        return EstimateResult(
            mode="fit", theta=theta, combined=combined, fits=fits,
            n_samples=n, score_norm=score,
            wall_s=time.perf_counter() - t0,
            compile_s=stats["compile_s"],
            new_compiles=(c1 - c0 if c0 >= 0 and c1 >= 0 else -1),
            comm_scalars=comm,
            telemetry=rec.snapshot(mark) if rec.enabled else None)

    def stream(self, capacity: Optional[int] = None):
        """Streaming verb: a :class:`~repro.stream.online.StreamingEstimator`
        bound to this plan — same family, mesh, fixed coordinates, and
        Newton budget, so its warm-started incremental re-fits hit the very
        bucket solvers ``fit`` compiled (and vice versa)."""
        from ..stream.online import StreamingEstimator
        return StreamingEstimator(
            self.graph, include_singleton=self.plan.include_singleton,
            theta_fixed=self.theta_fixed,
            capacity=capacity or self.plan.capacity,
            n_iter=self.plan.n_iter, family=self.family, mesh=self.mesh,
            want_influence=self.want_influence,
            window=self.plan.stream_window,
            discount=self.plan.stream_discount,
            recorder=self.recorder)

    def simulate(self, pool, **overrides):
        """An event-driven :class:`~repro.stream.simulator.StreamSimulator`
        configured from this plan (see ``StreamSimulator.from_plan``);
        ``overrides`` win, including an explicit ``mesh=``."""
        from ..stream.simulator import StreamSimulator
        overrides.setdefault("mesh", self.mesh)
        overrides.setdefault("telemetry", self.recorder)
        return StreamSimulator.from_plan(self.plan, pool, **overrides)

    def joint(self, X, sample_weight=None) -> EstimateResult:
        """Joint verb: ADMM MPLE (Sec. 3.2) through the batched proximal
        engine — one compiled solve per degree bucket per round, shared
        with ``fit``'s solver cache through the common engine."""
        rec = self.recorder
        mark = rec.mark()
        t0 = time.perf_counter()
        c0 = bucket_compile_count()
        stats = {"compile_s": 0.0}
        with rec.span("joint"):
            Xj = self._as_samples(X)
            n = int(Xj.shape[0])
            plan = self.plan
            fits = None
            if plan.admm_init != "zero":
                fits = self.fit_local(Xj, sample_weight=sample_weight,
                                      want_influence=False, stats=stats)
            res = admm_mple_family(
                self.graph, Xj, n_iters=plan.admm_iters,
                init=plan.admm_init, fits=fits,
                include_singleton=plan.include_singleton,
                theta_fixed=self.theta_fixed,
                newton_iters=plan.admm_newton_iters, family=self.family,
                mesh=self.mesh, sample_weight=sample_weight,
                rho0=plan.admm_rho, recorder=self.recorder, stats=stats)
            theta = res.trajectory[-1]
            score = self._score_norm(theta, Xj, n)
        c1 = bucket_compile_count()
        comm = plan.admm_iters * 2 * sum(len(b) for b in self.betas)
        if rec.enabled:
            rec.gauge("comm.scalars_per_round", comm, scheme="admm")
        return EstimateResult(
            mode="joint", theta=theta, combined={"admm": theta}, fits=fits,
            n_samples=n, score_norm=score,
            wall_s=time.perf_counter() - t0,
            compile_s=stats["compile_s"],
            new_compiles=(c1 - c0 if c0 >= 0 and c1 >= 0 else -1),
            comm_scalars={"admm": comm},
            trajectory=res.trajectory, primal_residual=res.primal_residual,
            telemetry=rec.snapshot(mark) if rec.enabled else None)

    def select(self, X, spec=None) -> "StructureResult":
        """Structure verb: estimate the GRAPH by distributed
        pseudo-likelihood lasso + support voting (:mod:`repro.structure`).

        Runs group-lasso neighborhood selection over a candidate edge set
        (``spec.policy``) along a warm-started descending lambda path —
        every ADMM round reuses the batched proximal engine, so the whole
        path compiles exactly one prox program per degree bucket of the
        candidate graph — picks lambda by EBIC, and reconciles the two
        endpoints' verdicts per candidate edge through the plan's vote
        rule. ``spec`` overrides ``plan.structure`` for this call;
        with neither, :class:`~repro.structure.StructureSpec` defaults
        apply. Note the plan's ``graph`` is NOT assumed correct — it only
        sizes the problem (p nodes); the candidate policy decides which
        edges are searched.
        """
        from ..stream.costs import structure_vote_scalars
        from ..structure import (StructureSpec, StructureResult,
                                 auto_lambda_grid, candidate_graph,
                                 debias_to_support, ebic_scores,
                                 edge_supports, get_vote_rule, lasso_path,
                                 reconcile)
        if spec is None:
            spec = self.plan.structure or StructureSpec()
        elif isinstance(spec, dict):
            spec = StructureSpec.from_dict(spec)
        rule = get_vote_rule(spec.vote)
        rec = self.recorder
        mark = rec.mark()
        t0 = time.perf_counter()
        c0_fit = bucket_compile_count()
        c0_prox = prox_compile_count()
        stats = {"compile_s": 0.0}
        family = self.family
        C = family.block_dim
        lead = 1 if self.plan.include_singleton else 0
        with rec.span("select"):
            Xj = self._as_samples(X)
            Xnp = np.asarray(Xj, dtype=np.float64)
            n, p = Xnp.shape
            if p != self.graph.p:
                raise ValueError(f"X has {p} columns; plan graph has "
                                 f"p={self.graph.p} nodes")

            with rec.span("screen", policy=spec.policy):
                gc = candidate_graph(spec, p, X=Xnp, family=family)
            # the plan's fixed coordinates remapped onto the candidate
            # graph: node blocks carry over, candidate-edge blocks are free
            tf_c = np.zeros(family.n_params(gc))
            tf_c[: p * C] = self.theta_fixed[: p * C]
            tf_cj = jnp.asarray(tf_c, Xj.dtype)

            lambdas = spec.lambdas or auto_lambda_grid(gc, Xnp, family, spec)

            # the dense (unpenalized) fit on the candidate graph: it pins
            # the path's lambda == 0 end to the fit verb, supplies the
            # weighted vote's sandwich-variance masses, and debiases the
            # EBIC likelihoods (shrunk iterates would drag selection
            # dense). Same engine call as session.fit, so a candidate
            # graph equal to the plan graph reuses its compiled programs.
            with rec.span("dense_fit"):
                fits_c = fit_all_local_batched(
                    gc, Xj,
                    include_singleton=self.plan.include_singleton,
                    theta_fixed=tf_cj, n_iter=self.plan.n_iter,
                    family=family, mesh=self.mesh,
                    want_influence=self.want_influence,
                    recorder=rec, stats=stats)
            dense_thetas = [np.asarray(f.theta, dtype=np.float64)
                            for f in fits_c]

            with rec.span("path", n_lambdas=len(lambdas)):
                path = lasso_path(
                    gc, Xj, lambdas, spec, family,
                    include_singleton=self.plan.include_singleton,
                    theta_fixed=tf_cj, dense_thetas=dense_thetas,
                    mesh=self.mesh, recorder=rec, stats=stats)
                ebic = ebic_scores(gc, Xnp, path, family, spec,
                                   self.plan.include_singleton, tf_c,
                                   debias_thetas=dense_thetas)

            with rec.span("vote", rule=rule.name):
                # per-endpoint vote masses: inverse sandwich variance of
                # the edge block (the combiner registry's second-order
                # info, computed by the same engine)
                mass = np.ones((p, gc.m))
                if rule.needs_mass:
                    for i in range(p):
                        ks = gc.incident_edges(i)
                        dv = np.diag(np.asarray(fits_c[i].V))
                        for idx, k in enumerate(ks):
                            blk = dv[(lead + idx) * C:(lead + idx + 1) * C]
                            mass[i, k] = 1.0 / max(float(np.mean(blk)),
                                                   1e-12)
                I = np.array([e[0] for e in gc.edges], dtype=np.int64)
                J = np.array([e[1] for e in gc.edges], dtype=np.int64)
                ar = np.arange(gc.m)
                keeps, margins_l, sizes = [], [], []
                for zs in path:
                    sup = edge_supports(gc, zs, C, lead)
                    keep, margin = reconcile(
                        sup[I, ar], sup[J, ar], rule,
                        mass_a=mass[I, ar], mass_b=mass[J, ar])
                    keeps.append(keep)
                    margins_l.append(margin)
                    sizes.append(int(keep.sum()))
                lsel = int(np.argmin(ebic))
                support = tuple(e for e, k in zip(gc.edges, keeps[lsel])
                                if k)
            comm = structure_vote_scalars(gc.m, rule.name)
            if rec.enabled:
                rec.gauge("structure.candidate_edges", gc.m)
                rec.gauge("structure.support_size", len(support))
                rec.gauge("comm.scalars_per_round", comm,
                          scheme=f"vote_{rule.name}")
        c1_fit = bucket_compile_count()
        c1_prox = prox_compile_count()
        path_compiles = (c1_prox - c0_prox
                         if c0_prox >= 0 and c1_prox >= 0 else -1)
        new_compiles = (path_compiles + c1_fit - c0_fit
                        if min(c0_fit, c1_fit, path_compiles) >= 0 else -1)
        return StructureResult(
            support=support, graph=Graph(p, support),
            candidate_edges=gc.edges, vote_rule=rule.name,
            margins=margins_l[lsel], lambdas=tuple(lambdas),
            lambda_selected=float(lambdas[lsel]), ebic=ebic,
            support_sizes=tuple(sizes),
            thetas=debias_to_support(gc, path[lsel], dense_thetas, C, lead),
            n_samples=n,
            comm_scalars=comm, wall_s=time.perf_counter() - t0,
            compile_s=stats["compile_s"], path_compiles=path_compiles,
            new_compiles=new_compiles,
            telemetry=rec.snapshot(mark) if rec.enabled else None)

    def __repr__(self) -> str:
        return (f"EstimationSession(family={self.plan.family!r}, "
                f"p={self.graph.p}, m={self.graph.m}, "
                f"buckets={self.n_buckets}, "
                f"combiners={list(self.plan.combiners)}, "
                f"mesh={self.plan.mesh!r}, fit_calls={self.fit_calls})")


def compile_plan(plan: Plan, mesh=None) -> EstimationSession:
    """Functional alias for ``EstimationSession.for_plan`` (cached)."""
    return EstimationSession.for_plan(plan, mesh=mesh)
