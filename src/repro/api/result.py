"""Structured results of the estimation-plan API.

Every session verb returns an :class:`EstimateResult` — one typed record
carrying the headline estimate, the per-scheme combined estimates, the
per-node local fits, the pseudo-score convergence diagnostic, wall/compile
counters, and the communication-cost scalars the paper's claims are about —
replacing the heterogeneous ``List[LocalFit]`` / bare-ndarray returns of
the legacy entry points.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core.consensus import mse as _mse
from ..core.estimators import LocalFit


@dataclasses.dataclass
class EstimateResult:
    """One estimation outcome, fully accounted.

    mode            — "fit" (local fits + one-step consensus) or "joint"
                      (ADMM joint MPLE).
    theta           — the headline flat estimate: the plan's first
                      combiner for ``fit``, the final ADMM iterate for
                      ``joint``.
    combined        — per-scheme combined estimates (every combiner the
                      plan requested), name -> flat theta.
    fits            — per-node :class:`LocalFit` results (None when the
                      verb never produced them, e.g. zero-init ADMM).
    n_samples       — rows of the sample matrix the verb consumed.
    score_norm      — ||grad pseudo-loglik(theta)|| over those samples;
                      the model-free convergence diagnostic.
    wall_s          — wall-clock of the verb, compile time included
                      (backward-compatible: still the total).
    compile_s       — wall-clock spent in bucket-solver dispatches that
                      triggered a compilation (measured around the
                      first-dispatch path; 0.0 on a warm session), so
                      warm-vs-cold comparisons can subtract it.
    new_compiles    — bucket-solver compilations this call triggered
                      (0 on a warm session; -1 if the jit-cache probe is
                      unavailable).
    comm_scalars    — scalars a sensor network would transmit to realize
                      each requested scheme (the shared accounting of
                      ``repro.stream.costs``), name -> count; ``joint``
                      reports the K-round ADMM exchange as "admm".
    trajectory      — (admm_iters + 1, n_params) consensus iterates
                      (``joint`` only).
    primal_residual — (admm_iters,) rms primal residuals (``joint`` only).
    telemetry       — :class:`~repro.telemetry.TelemetrySnapshot` of the
                      verb's spans/metrics when the plan declares a
                      :class:`~repro.telemetry.TelemetrySpec`; None when
                      telemetry is off.
    """

    mode: str
    theta: np.ndarray
    combined: Dict[str, np.ndarray]
    fits: Optional[List[LocalFit]]
    n_samples: int
    score_norm: float
    wall_s: float
    new_compiles: int
    comm_scalars: Dict[str, int]
    trajectory: Optional[np.ndarray] = None
    primal_residual: Optional[np.ndarray] = None
    compile_s: float = 0.0
    telemetry: Optional[object] = None

    def mse(self, theta_star: np.ndarray, free=None) -> float:
        """||theta - theta*||^2 over ``free`` (default: all) coordinates."""
        return _mse(self.theta, np.asarray(theta_star), free)

    def __repr__(self) -> str:       # compact, log-friendly
        extras = ""
        if self.trajectory is not None:
            extras = f", admm_iters={len(self.trajectory) - 1}"
        return (f"EstimateResult(mode={self.mode!r}, "
                f"schemes={sorted(self.combined)}, n={self.n_samples}, "
                f"score_norm={self.score_norm:.3e}, "
                f"wall_s={self.wall_s:.3f}, "
                f"new_compiles={self.new_compiles}{extras})")
