"""End-to-end driver: pod-consensus training of a transformer LM.

Compares the paper's consensus schemes (uniform / Fisher-diagonal / max /
ADMM) against fully-synchronous data parallelism on the same token budget.
Cross-pod communication drops by ~h_steps x for one-step schemes.

Defaults are CPU-runnable (a ~10M-param llama-style model, 40 rounds).
--full trains a ~100M-param model for a few hundred steps (slow on CPU,
sized for a single v5e host).

    PYTHONPATH=src python examples/consensus_training.py [--full]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

import repro.configs as CFG
from repro.data.pipeline import DataConfig, SyntheticLM, pod_sharded_batches
from repro.optim import adamw
from repro.train import consensus as CT
from repro.train import step as TS


def model_cfg(full: bool):
    base = CFG.get("llama3.2-3b")
    if full:
        # ~100M params: 12L, d=768, 12H
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32000, dtype="float32")
    return dataclasses.replace(
        base, n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=4096, dtype="float32")


def run_scheme(cfg, scheme, rounds, h_steps, n_pods, batch, seq, lr):
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                global_batch=batch * n_pods, seed=0))
    ocfg = adamw.AdamWConfig(lr=lr, warmup_steps=rounds * h_steps // 10 + 1,
                             total_steps=rounds * h_steps)
    tcfg = TS.TrainConfig()
    if scheme == "sync":
        state = TS.init_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(TS.make_train_step(cfg, ocfg, tcfg))
        losses = []
        for i in range(rounds * h_steps):
            batch_i = ds.batch(i)
            state, metrics = step(state, batch_i)
            losses.append(float(metrics["nll"]))
        comm_units = rounds * h_steps          # grad all-reduce every step
        return losses, comm_units
    ccfg = CT.ConsensusConfig(n_pods=n_pods, scheme=scheme, h_steps=h_steps)
    state = CT.init_state(cfg, jax.random.PRNGKey(0), ccfg)
    round_step = jax.jit(CT.make_round_step(cfg, ocfg, tcfg, ccfg))
    losses = []
    for r, b in zip(range(rounds), pod_sharded_batches(ds, n_pods, h_steps)):
        state, metrics = round_step(state, b)
        losses.append(float(metrics["nll"]))
    comm_units = rounds                        # one combine per round
    return losses, comm_units


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--h-steps", type=int, default=4)
    ap.add_argument("--pods", type=int, default=2)
    args = ap.parse_args()

    cfg = model_cfg(args.full)
    rounds = args.rounds or (75 if args.full else 40)
    batch, seq = (8, 512) if args.full else (4, 128)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(
        __import__("repro.models.transformer",
                   fromlist=["model_abstract"]).model_abstract(cfg)))
    print(f"model: {cfg.arch_id}-style, {n_params/1e6:.1f}M params; "
          f"{rounds} rounds x {args.h_steps} local steps x {args.pods} pods")

    results = {}
    for scheme in ("sync", "uniform", "diagonal", "max", "admm"):
        t0 = time.time()
        losses, comm = run_scheme(cfg, scheme, rounds, args.h_steps,
                                  args.pods, batch, seq, lr=3e-3)
        results[scheme] = (losses, comm)
        print(f"{scheme:9s} final nll={losses[-1]:.4f} "
              f"cross-pod rounds={comm:4d} ({time.time()-t0:.0f}s)")
    sync_loss = results["sync"][0][-1]
    print("\nscheme     final_nll  vs_sync  cross-pod_comm_reduction")
    for scheme, (losses, comm) in results.items():
        red = results["sync"][1] / comm
        print(f"{scheme:9s} {losses[-1]:9.4f} {losses[-1]-sync_loss:+8.4f}"
              f"  {red:4.1f}x")


if __name__ == "__main__":
    main()
