"""Streaming sensors: the paper's any-time claim as a live system.

A 3x4 grid of sensors observes an Ising field. The whole setup is ONE
declarative `Plan` — the same plan whose `fit` verb would solve the batch
problem configures the event-driven simulator via
`StreamSimulator.from_plan`: samples trickle in at heterogeneous Poisson
rates, sensors re-fit their local conditional-likelihood estimators
incrementally (warm-started batched Newton over a shape-stable buffer),
and estimates of shared couplings travel to neighbors over a lossy, laggy
message network. Query the network at any round and you get a consistent
estimate whose error shrinks as data and messages flow — while total
communication stays a tiny fraction of centralizing the data.

    PYTHONPATH=src python examples/streaming_sensors.py
"""
import jax
import numpy as np

import repro.api as A
import repro.core as C
import repro.stream as S


def main():
    g = C.grid_graph(3, 4)
    model = C.random_model(g, sigma_pair=0.5, sigma_single=0.5,
                           key=jax.random.PRNGKey(0))
    theta_star = np.asarray(model.theta)
    pool = np.asarray(C.exact_sample(model, 4000, jax.random.PRNGKey(1)))

    # one plan: graph + family + scheme + buffer capacity; the simulator,
    # the streaming estimator, and the batch verb all read the same object
    plan = A.Plan(graph=g, family="ising", combiners=("diagonal",),
                  capacity=256)

    rounds = 15
    net = S.NetworkConfig(drop_prob=0.2, delay=1, jitter=1, seed=42)
    sim = S.StreamSimulator.from_plan(
        plan, pool, theta_star=theta_star, network=net,
        arrivals=S.ArrivalSpec(kind="poisson", rate=40.0), seed=7)
    res = sim.run(rounds, record_score=True)

    central = S.comm_costs(g, int(res.samples_seen[-1]), 20)["centralized"]
    print(f"{'round':>5s} {'n/node':>7s} {'scalars':>8s} {'stale':>6s} "
          f"{'|score|':>8s} {'MSE':>8s}")
    for k in range(len(res.rounds)):
        print(f"{res.rounds[k]:5d} {res.samples_seen[k]:7.0f} "
              f"{res.scalars_sent[k]:8d} {res.staleness[k]:6.2f} "
              f"{res.score_norm[k]:8.4f} {res.err[k]:8.4f}")

    print(f"\nany-time query, round 0:  MSE="
          f"{C.mse(res.estimate_at(0), theta_star):.4f}  "
          f"(the documented initial estimate — no data yet)")
    print(f"any-time query, round 5:  MSE="
          f"{C.mse(res.estimate_at(5), theta_star):.4f}")
    print(f"any-time query, round {rounds}: MSE="
          f"{C.mse(res.estimate_at(rounds), theta_star):.4f}")
    print(f"\nscalars communicated: {res.scalars_sent[-1]} "
          f"(centralizing the same data: {central})")

    # the batch verb of the SAME plan is the oracle endpoint: what a
    # fusion center would compute from everything the network has seen
    sess = plan.session()
    batch = sess.fit(pool[: int(res.samples_seen[-1])])
    print(f"same plan, batch verb:    MSE="
          f"{batch.mse(theta_star):.4f} (oracle on all arrived data)")


if __name__ == "__main__":
    main()
