"""Batched serving demo: prefill + KV-cache decode over a request batch,
including a sliding-window long-context request (the long_500k path at
CPU-friendly scale).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

import repro.configs as CFG
from repro.models import transformer as T
from repro.models import decoding as E


def main():
    cfg = CFG.reduced(CFG.get("llama3.2-3b"))
    params = T.model_init(cfg, jax.random.PRNGKey(0))

    # --- batched requests, shared-length prompt (static-shape serving) ----
    batch, prompt_len, new = 4, 24, 12
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    out = E.generate(cfg, params, prompts, new)
    print(f"full-attention batch={batch}: {out.shape} "
          f"in {time.time()-t0:.1f}s")

    # --- long-context request via sliding window (bounded cache) ----------
    t0 = time.time()
    out_w = E.generate(cfg, params, prompts, new, window_override=16)
    print(f"sliding-window (w=16) batch={batch}: {out_w.shape} "
          f"in {time.time()-t0:.1f}s — cache bounded at window size")

    # --- greedy determinism check -----------------------------------------
    out2 = E.generate(cfg, params, prompts, new)
    same = bool(jnp.all(out == out2))
    print(f"greedy decode deterministic: {same}")

    # --- recurrent arch: O(1) state instead of KV cache --------------------
    rg = CFG.reduced(CFG.get("xlstm-1.3b"))
    rparams = T.model_init(rg, jax.random.PRNGKey(2))
    rp = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, rg.vocab_size)
    t0 = time.time()
    rout = E.generate(rg, rparams, rp, 8)
    print(f"xlstm (attention-free) decode: {rout.shape} "
          f"in {time.time()-t0:.1f}s — state is (C, n, m), not a KV cache")


if __name__ == "__main__":
    main()
