"""Faithful sensor-network execution: one DEVICE per SENSOR via shard_map.

Each of the 8 host devices plays one sensor of an 8-node star-graph Ising
model: it sees only its local data X_A(i), fits its conditional-likelihood
estimator with a fixed-iteration Newton solve (pure lax — SPMD-safe), and
the consensus happens through jax.lax collectives (all_gather of the
per-sensor estimates + weights). This is the paper's Sec. 3 *system*, not
just its math: data never leaves the sensor; only O(deg) scalars do.

    python examples/sensor_network_shardmap.py     (sets its own XLA_FLAGS)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402

import repro.core as C          # noqa: E402

P_NODES = 8
N = 2000


def main():
    g = C.star_graph(P_NODES)
    model = C.random_model(g, 0.5, 0.5, jax.random.PRNGKey(0))
    theta_star = np.asarray(model.theta)
    X = C.exact_sample(model, N, jax.random.PRNGKey(1))

    # --- per-sensor views, padded to max degree for SPMD uniformity -------
    dmax = max(g.degree(i) for i in range(g.p))
    Z = np.zeros((g.p, N, dmax), np.float32)       # neighbor designs
    M = np.zeros((g.p, dmax), np.float32)          # valid-coordinate mask
    xi = np.zeros((g.p, N), np.float32)
    for i in range(g.p):
        nb = [g.edges[k][0] if g.edges[k][1] == i else g.edges[k][1]
              for k in g.incident_edges(i)]
        Z[i, :, : len(nb)] = np.asarray(X)[:, nb]
        M[i, : len(nb)] = 1.0
        xi[i] = np.asarray(X)[:, i]

    mesh = jax.make_mesh((P_NODES,), ("sensor",))

    def sensor_program(Z, xi, mask):
        """Runs ON each sensor device; sees only that sensor's shard."""
        Z, xi, mask = Z[0], xi[0], mask[0]          # local block

        def nll_grad_hess(w):
            eta = Z @ (w[1:] * mask) + w[0]
            r = 2.0 * xi * jax.nn.sigmoid(-2.0 * xi * eta)
            zb = jnp.concatenate([jnp.ones((N, 1)), Z * mask], 1)
            gvec = (r[:, None] * zb).mean(0)
            s = 4.0 * jax.nn.sigmoid(2.0 * xi * eta) * \
                jax.nn.sigmoid(-2.0 * xi * eta)
            H = (zb * s[:, None]).T @ zb / N + \
                1e-4 * jnp.eye(1 + Z.shape[1])      # ridge keeps padding sane
            return gvec, H, zb

        w = jnp.zeros(1 + Z.shape[1])
        for _ in range(25):                          # fixed-iteration Newton
            gvec, H, zb = nll_grad_hess(w)
            w = w + jnp.linalg.solve(H, gvec)
        # local inverse-variance weights (Prop 4.4: no extra communication)
        gvec, H, zb = nll_grad_hess(w)
        eta = Z @ (w[1:] * mask) + w[0]
        r = 2.0 * xi * jax.nn.sigmoid(-2.0 * xi * eta)
        G = r[:, None] * zb
        J = G.T @ G / N
        Hinv = jnp.linalg.inv(H)
        V = Hinv @ J @ Hinv
        wts = 1.0 / jnp.maximum(jnp.diag(V)[1:], 1e-9) * mask
        # the ONLY communication: per-sensor (estimate, weight) vectors
        all_est = jax.lax.all_gather(w[1:] * mask, "sensor")   # (p, dmax)
        all_wts = jax.lax.all_gather(wts, "sensor")            # (p, dmax)
        return all_est[None], all_wts[None]

    fn = shard_map(sensor_program, mesh=mesh,
                   in_specs=(P("sensor"), P("sensor"), P("sensor")),
                   out_specs=(P("sensor"), P("sensor")))
    est, wts = jax.jit(fn)(jnp.asarray(Z), jnp.asarray(xi), jnp.asarray(M))
    est, wts = np.asarray(est[0]), np.asarray(wts[0])

    # --- per-edge consensus (every sensor can do this locally) ------------
    theta_max = np.zeros(g.n_params)
    theta_lin = np.zeros(g.n_params)
    for k, (i, j) in enumerate(g.edges):
        pos_i = g.incident_edges(i).index(k)
        pos_j = g.incident_edges(j).index(k)
        cand = np.array([est[i, pos_i], est[j, pos_j]])
        ww = np.array([wts[i, pos_i], wts[j, pos_j]])
        theta_max[g.p + k] = cand[np.argmax(ww)]
        theta_lin[g.p + k] = (ww * cand).sum() / ww.sum()

    free = C.free_indices(g, include_singleton=False)
    print(f"devices = {jax.device_count()} (one per sensor)")
    print(f"max-consensus    MSE: {C.mse(theta_max, theta_star, free):.5f}")
    print(f"linear-diagonal  MSE: {C.mse(theta_lin, theta_star, free):.5f}")
    # centralized reference
    fits = C.fit_all_local(g, X, include_singleton=False,
                           theta_fixed=jnp.asarray(theta_star))
    ref = C.combine(g, fits, "max", include_singleton=False,
                    theta_fixed=theta_star)
    print(f"centralized max  MSE: {C.mse(ref, theta_star, free):.5f} "
          f"(should be close)")


if __name__ == "__main__":
    main()
