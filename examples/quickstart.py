"""Quickstart: the paper in one page.

Estimate a star-graph Ising model from samples with every method in the
paper and compare against exact asymptotic theory.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

import repro.core as C


def main():
    # 1. a 10-node star-graph Ising model (the paper's hard case)
    g = C.star_graph(10)
    model = C.random_model(g, sigma_pair=0.5, sigma_single=0.5,
                           key=jax.random.PRNGKey(0))
    theta_star = np.asarray(model.theta)

    # 2. n samples, stored per-sensor: sensor i sees only X_{A(i)}
    X = C.exact_sample(model, n=3000, key=jax.random.PRNGKey(1))

    # 3. each sensor fits its local conditional-likelihood estimator (Eq. 3)
    fits = C.fit_all_local(g, X)

    # 4. one-step consensus combinations (Sec. 3.1)
    print(f"{'method':18s} {'MSE':>9s}")
    for scheme in ("uniform", "diagonal", "optimal", "max", "matrix"):
        theta = C.combine(g, fits, scheme)
        print(f"one-step {scheme:9s} {C.mse(theta, theta_star):9.5f}")

    # 5. joint MPLE — centralized reference (Eq. 2)
    theta_mple = C.fit_mple(g, X)
    print(f"{'joint MPLE':18s} {C.mse(theta_mple, theta_star):9.5f}")

    # 6. ADMM: distributed joint MPLE with any-time iterates (Sec. 3.2)
    res = C.admm_mple(g, X, n_iters=10, init="diagonal", fits=fits)
    print(f"{'ADMM (10 iters)':18s} "
          f"{C.mse(res.trajectory[-1], theta_star):9.5f}")

    # 7. exact asymptotic efficiency vs the MLE floor (Sec. 4, Fig 2b)
    locs = C.exact_locals(model, include_singleton=False)
    tr_mle, _ = C.exact_mle_variance(model, include_singleton=False)
    print("\nexact asymptotic efficiency tr(V)/tr(V_mle)  (1.0 = optimal):")
    for scheme in ("uniform", "diagonal", "optimal", "max"):
        tr, _ = C.exact_consensus_variance(model, locs, scheme,
                                           include_singleton=False)
        print(f"  {scheme:9s} {tr / tr_mle:6.3f}")
    tr_j, _ = C.exact_joint_mple_variance(model, include_singleton=False)
    print(f"  {'joint':9s} {tr_j / tr_mle:6.3f}")


if __name__ == "__main__":
    main()
