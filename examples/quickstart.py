"""Quickstart: the paper in one page, through the estimation-plan API.

Declare the whole problem once as a `Plan` (graph + family + combiners +
solver options), compile it into an `EstimationSession`, and run every
method in the paper through the session's three verbs — batch `fit`
(local CL estimators + one-step consensus, Sec. 3.1), `joint` (ADMM joint
MPLE, Sec. 3.2), and `stream()` (the any-time engine; see
streaming_sensors.py) — then compare against exact asymptotic theory.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

import repro.api as A
import repro.core as C


def main():
    # 1. a 10-node star-graph Ising model (the paper's hard case)
    g = C.star_graph(10)
    model = C.random_model(g, sigma_pair=0.5, sigma_single=0.5,
                           key=jax.random.PRNGKey(0))
    theta_star = np.asarray(model.theta)

    # 2. n samples, stored per-sensor: sensor i sees only X_{A(i)}
    X = C.exact_sample(model, n=3000, key=jax.random.PRNGKey(1))

    # 3. ONE declarative plan covers the whole design space: every
    #    registered combiner requested up front; the compiled session
    #    computes second-order objects only because "optimal" asks
    plan = A.Plan(graph=g,
                  family="ising",
                  combiners=("uniform", "diagonal", "optimal", "max",
                             "weighted_vote", "matrix"))
    sess = plan.session()

    # 4. batch verb: local CL fits (Eq. 3) + all one-step consensus
    #    combinations (Sec. 3.1) in one structured result
    res = sess.fit(X)
    print(f"{'method':22s} {'MSE':>9s}")
    for scheme, theta in sorted(res.combined.items()):
        print(f"one-step {scheme:13s} {C.mse(theta, theta_star):9.5f}")
    print(f"(fit: n={res.n_samples}, |score|={res.score_norm:.4f}, "
          f"wall={res.wall_s:.2f}s, new_compiles={res.new_compiles})")

    # 5. joint MPLE — centralized reference (Eq. 2)
    theta_mple = C.fit_mple(g, X)
    print(f"{'joint MPLE':22s} {C.mse(theta_mple, theta_star):9.5f}")

    # 6. joint verb: distributed joint MPLE via ADMM with any-time
    #    iterates (Sec. 3.2), sharing the session's compiled solvers
    joint = sess.joint(X)
    print(f"{'ADMM (' + str(plan.admm_iters) + ' iters)':22s} "
          f"{joint.mse(theta_star):9.5f}   "
          f"(comm: {joint.comm_scalars['admm']} scalars)")

    # 7. exact asymptotic efficiency vs the MLE floor (Sec. 4, Fig 2b)
    locs = C.exact_locals(model, include_singleton=False)
    tr_mle, _ = C.exact_mle_variance(model, include_singleton=False)
    print("\nexact asymptotic efficiency tr(V)/tr(V_mle)  (1.0 = optimal):")
    for scheme in ("uniform", "diagonal", "optimal", "max"):
        tr, _ = C.exact_consensus_variance(model, locs, scheme,
                                           include_singleton=False)
        print(f"  {scheme:9s} {tr / tr_mle:6.3f}")
    tr_j, _ = C.exact_joint_mple_variance(model, include_singleton=False)
    print(f"  {'joint':9s} {tr_j / tr_mle:6.3f}")

    # 8. plans are values: serialize, reload, get the SAME cached session
    plan2 = A.Plan.from_dict(plan.to_dict())
    assert plan2 == plan and plan2.session() is sess
    print("\nplan round-trips via to_dict/from_dict; equal plans share "
          "one compiled session")


if __name__ == "__main__":
    main()
