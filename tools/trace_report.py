"""Render a markdown run report from a telemetry JSONL event log.

    PYTHONPATH=src python tools/trace_report.py BENCH_stream_trace.jsonl

Sections:

* **Phase breakdown** — every span path with call count, total/mean wall
  seconds, and the bucket-solver compile-count delta attributed to it.
* **Metrics** — counter totals, final gauge values, histogram summaries.
* **Any-time curve** — the error-vs-scalars-sent trajectory assembled
  from the ``point`` timeline events (the measurable form of the paper's
  any-time claim), rendered as a table plus a coarse ASCII sparkline.
* **Network ledger** — the comm accounting replayed from the per-message
  ``net.*`` counter events, including the conservation check
  ``sent == delivered + dropped + in_flight``.
* **Fault timeline** — every fault injection (byzantine / replay / drift
  / crash gauge changes) in round order.

Reads only the JSONL file — the report is reproducible from the artifact
alone, no live process needed.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.telemetry import (read_events, replay_network_counters,  # noqa
                             timeline_from_events)
from repro.telemetry.recorder import TelemetrySnapshot  # noqa


BARS = " ▁▂▃▄▅▆▇█"


def sparkline(values):
    if not values:
        return ""
    lo, hi = min(values), max(values)
    span = (hi - lo) or 1.0
    return "".join(BARS[1 + int((v - lo) / span * (len(BARS) - 2))]
                   for v in values)


def phase_breakdown(snap):
    print("## Phase breakdown\n")
    if not snap.spans:
        print("(no spans recorded)\n")
        return
    print("| span | count | total s | mean s | new compiles |")
    print("|---|---|---|---|---|")
    for path in sorted(snap.spans,
                       key=lambda k: -snap.spans[k]["total_s"]):
        agg = snap.spans[path]
        mean = agg["total_s"] / max(agg["count"], 1)
        print(f"| `{path}` | {agg['count']} | {agg['total_s']:.3f} | "
              f"{mean:.4f} | {agg['new_compiles']} |")
    print()


def metrics(snap):
    print("## Metrics\n")
    if not (snap.counters or snap.gauges or snap.histograms):
        print("(no metrics recorded)\n")
        return
    if snap.counters:
        print("| counter | total |")
        print("|---|---|")
        for name in sorted(snap.counters):
            print(f"| `{name}` | {snap.counters[name]} |")
        print()
    if snap.gauges:
        print("| gauge | last value |")
        print("|---|---|")
        for name in sorted(snap.gauges):
            print(f"| `{name}` | {snap.gauges[name]} |")
        print()
    if snap.histograms:
        print("| histogram | n | min | mean | max |")
        print("|---|---|---|---|---|")
        for name in sorted(snap.histograms):
            obs = snap.histograms[name]
            mean = sum(obs) / len(obs)
            print(f"| `{name}` | {len(obs)} | {min(obs):.4g} | "
                  f"{mean:.4g} | {max(obs):.4g} |")
        print()


def anytime_curve(events):
    print("## Any-time curve (error vs scalars sent)\n")
    try:
        rounds, err = timeline_from_events(events, "err")
        _, scal = timeline_from_events(events, "scalars_sent")
    except KeyError as e:
        print(f"(not recorded: {e})\n")
        return
    print("| round | scalars sent | err |")
    print("|---|---|---|")
    for r, s, e in zip(rounds, scal, err):
        print(f"| {int(r)} | {int(s)} | {e:.6g} |")
    print(f"\nerr trajectory: `{sparkline(list(err))}`\n")


def network_ledger(events):
    print("## Network ledger (replayed from per-message events)\n")
    c = replay_network_counters(events)
    if c["msgs_sent"] == 0:
        print("(no network traffic recorded)\n")
        return
    print("| counter | value |")
    print("|---|---|")
    for key in ("msgs_sent", "msgs_dropped", "msgs_delivered", "in_flight",
                "scalars_sent", "scalars_dropped", "scalars_delivered",
                "scalars_in_flight"):
        print(f"| {key} | {c[key]} |")
    ok = (c["scalars_sent"] == c["scalars_delivered"]
          + c["scalars_dropped"] + c["scalars_in_flight"])
    print(f"\nscalar conservation (`sent == delivered + dropped + "
          f"in_flight`): **{'holds' if ok else 'VIOLATED'}**\n")


def fault_timeline(events):
    print("## Fault timeline\n")
    rows = []
    for ev in events:
        tags = ev.get("tags") or {}
        if ev["kind"] == "counter" and ev["name"] == "fault.injections":
            rnd = tags.get("round", "?")
            detail = ", ".join(f"{k}={v}" for k, v in sorted(tags.items())
                               if k != "round")
            rows.append((rnd, detail))
        elif ev["kind"] == "gauge" and ev["name"] == "fault.nodes_down":
            rows.append((tags.get("round", "?"),
                         f"kind=crash, nodes_down={ev['value']}"))
    if not rows:
        print("(no faults fired)\n")
        return
    print("| round | injection |")
    print("|---|---|")
    last_crash = None
    for rnd, detail in rows:
        if detail.startswith("kind=crash"):
            if detail == last_crash:      # only report crash-mask changes
                continue
            last_crash = detail
        print(f"| {rnd} | {detail} |")
    print()


def main():
    ap = argparse.ArgumentParser(
        description="markdown run report from a telemetry JSONL log")
    ap.add_argument("jsonl", help="path to the event log")
    args = ap.parse_args()
    events = read_events(args.jsonl)
    if not events:
        sys.exit(f"{args.jsonl}: no events")
    snap = TelemetrySnapshot.from_events(events)
    print(f"# Telemetry run report\n\n`{args.jsonl}` — "
          f"{len(events)} events, "
          f"{events[-1]['t'] - events[0]['t']:.3f} s span\n")
    phase_breakdown(snap)
    metrics(snap)
    anytime_curve(events)
    network_ledger(events)
    fault_timeline(snap.events)


if __name__ == "__main__":
    main()
