"""Generate the EXPERIMENTS.md dry-run + roofline markdown tables from
experiments/dryrun/*.json.

The records are not checked in — generate them first with the dry-run
harness (its ``--out`` default is exactly the directory this script reads):

    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
    PYTHONPATH=src python tools/gen_tables.py > experiments/tables.md
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src"); sys.path.insert(0, ".")

import repro.configs as CFG               # noqa: E402
from benchmarks.roofline import (model_flops_per_device, PEAK, HBM,   # noqa
                                 LINK)


def fmt(x, unit=""):
    if x is None:
        return "-"
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(x) >= div:
            return f"{x/div:.2f}{suf}{unit}"
    return f"{x:.2f}{unit}"


def main():
    recs = {}
    paths = sorted(glob.glob("experiments/dryrun/*.json"))
    if not paths:
        print("no dry-run records found under experiments/dryrun/ — "
              "generate them first:\n"
              "    PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--out experiments/dryrun", file=sys.stderr)
        print("### Dry-run\n\n(no records)\n\n### Roofline\n\n(no records)")
        return
    for path in paths:
        r = json.load(open(path))
        if "arch" in r:
            recs[(r["arch"], r["shape"], r["mesh"])] = r

    print("### Dry-run (all 40 combos x 2 meshes)\n")
    print("| arch | shape | mesh | ok | compile_s | args GiB/dev | "
          "temp GiB/dev | dot FLOPs/dev | HBM B/dev | coll B/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if not r.get("ok"):
            print(f"| {arch} | {shape} | {mesh} | FAIL | - | - | - | - | - "
                  f"| {r.get('error', '?')[:40]} |")
            continue
        m = r["memory"]
        print(f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} | "
              f"{m['argument_bytes']/2**30:.2f} | "
              f"{m['temp_bytes']/2**30:.2f} | "
              f"{fmt(r.get('dot_flops'))} | {fmt(r.get('hbm_bytes'))} | "
              f"{fmt(r.get('collective_bytes_total'))} |")

    print("\n### Roofline (single-pod 16x16, per device)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL_FLOPs/dev | useful ratio | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if mesh != "16x16" or not r.get("ok"):
            continue
        cfg = CFG.get(arch)
        tc = r.get("dot_flops", 0) / PEAK
        tm = r.get("hbm_bytes", 0) / HBM
        tl = r.get("collective_bytes_total", 0) / LINK
        dom = max((("compute", tc), ("memory", tm), ("collective", tl)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops_per_device(cfg, shape)
        ratio = mf / r["dot_flops"] if r.get("dot_flops") else float("nan")
        note = ""
        if r.get("window_override"):
            note = f"SWA w={r['window_override']}"
        print(f"| {arch} | {shape} | {tc:.2e} | {tm:.2e} | {tl:.2e} | "
              f"{dom} | {fmt(mf)} | {ratio:.2f} | {note} |")


if __name__ == "__main__":
    main()
