"""Generate markdown tables from the machine-readable benchmark records:
the EXPERIMENTS.md dry-run + roofline tables from experiments/dryrun/*.json
and the streaming/hostile-network tables from BENCH_stream.json.

The dry-run records are not checked in — generate them first with the
dry-run harness (its ``--out`` default is exactly the directory this
script reads):

    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
    PYTHONPATH=src python tools/gen_tables.py > experiments/tables.md

BENCH_stream.json is produced by ``python -m benchmarks.anytime_stream``.
Records carrying an unknown ``schema_version`` are REJECTED loudly (exit
1) rather than rendered wrong: a version this reader does not know means
the payload layout changed after this script was written.
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src"); sys.path.insert(0, ".")

import repro.configs as CFG               # noqa: E402
from benchmarks.roofline import (model_flops_per_device, PEAK, HBM,   # noqa
                                 LINK)


def fmt(x, unit=""):
    if x is None:
        return "-"
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(x) >= div:
            return f"{x/div:.2f}{suf}{unit}"
    return f"{x:.2f}{unit}"


#: BENCH_*.json schema versions this reader understands. 1 == the
#: pre-provenance payloads, which carried no version stamp at all.
KNOWN_SCHEMA_VERSIONS = (1, 2)


def check_schema(payload: dict, path: str) -> None:
    """Refuse to render a BENCH record whose schema this script predates."""
    version = payload.get("schema_version", 1)
    if version not in KNOWN_SCHEMA_VERSIONS:
        sys.exit(
            f"{path}: schema_version {version!r} is unknown to this "
            f"reader (understands {list(KNOWN_SCHEMA_VERSIONS)}); "
            f"regenerate the record or update tools/gen_tables.py")


def stream_tables():
    """Render BENCH_stream.json: per-graph any-time rows plus the PR 6
    hostile-network section (Byzantine robustness, drift tracking,
    crash/restart, durable restore)."""
    path = "BENCH_stream.json"
    print("\n### Streaming any-time trajectories (BENCH_stream.json)\n")
    if not os.path.exists(path):
        print("(no record — run `python -m benchmarks.anytime_stream`)")
        return
    payload = json.load(open(path))
    check_schema(payload, path)
    prov = payload.get("provenance")
    if prov:
        print(f"_{prov.get('backend', '?')}/{prov.get('kernel_mode', '?')}"
              f", {prov.get('git_sha', 'unknown')[:12]}, "
              f"{prov.get('timestamp', '?')}_\n")
    print("| graph | method | err first | err last | samples/node | "
          "scalars sent |")
    print("|---|---|---|---|---|---|")
    for gname, rec in sorted(payload.get("graphs", {}).items()):
        for meth, tr in sorted(rec.get("methods", {}).items()):
            err = tr["err"]
            print(f"| {gname} | {meth} | {err[0]:.4f} | {err[-1]:.4f} | "
                  f"{tr['samples_seen'][-1]:.0f} | "
                  f"{tr['scalars_sent'][-1]} |")

    hostile = payload.get("hostile")
    if not hostile:
        return
    print("\n### Hostile network (star10, 20% Byzantine)\n")
    print("| scenario | fault-free err | hostile err | note |")
    print("|---|---|---|---|")
    meths = hostile.get("methods", {})
    for scheme in ("uniform", "trimmed_mean", "krum"):
        row = meths.get(f"byzantine_{scheme}")
        if row is None:
            continue
        ratio = row["err_hostile"] / max(row["err_fault_free"], 1e-12)
        print(f"| byzantine sign-flip / {scheme} | "
              f"{row['err_fault_free']:.4f} | {row['err_hostile']:.4f} | "
              f"{ratio:.1f}x fault-free |")
    if "drift" in meths:
        d = meths["drift"]
        print(f"| change-point drift | {d['err_plain']:.4f} (plain) | "
              f"{d['err_windowed']:.4f} (windowed) | windowed re-fit "
              f"tracks |")
    if "crash_restart" in meths:
        err = meths["crash_restart"]["err"]
        print(f"| crash/restart | {err[0]:.4f} | {err[-1]:.4f} | "
              f"survivors keep converging |")
    if "kill_restore" in meths:
        md = meths["kill_restore"]["restore_maxdiff"]
        print(f"| kill + durable restore | - | {md:.1e} | max traj diff "
              f"vs uninterrupted |")
    tel = hostile.get("telemetry")
    if tel:
        print(f"| telemetry replay | - | - | {tel['events']} events, "
              f"{tel['fault_injections']} faults fired, "
              f"{tel['robust_rejections']} robust rejections, "
              f"replayed scalars {tel['scalars_sent_replayed']} |")


def main():
    recs = {}
    paths = sorted(glob.glob("experiments/dryrun/*.json"))
    if not paths:
        print("no dry-run records found under experiments/dryrun/ — "
              "generate them first:\n"
              "    PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--out experiments/dryrun", file=sys.stderr)
        print("### Dry-run\n\n(no records)\n\n### Roofline\n\n(no records)")
        stream_tables()
        return
    for path in paths:
        r = json.load(open(path))
        if "arch" in r:
            recs[(r["arch"], r["shape"], r["mesh"])] = r

    print("### Dry-run (all 40 combos x 2 meshes)\n")
    print("| arch | shape | mesh | ok | compile_s | args GiB/dev | "
          "temp GiB/dev | dot FLOPs/dev | HBM B/dev | coll B/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if not r.get("ok"):
            print(f"| {arch} | {shape} | {mesh} | FAIL | - | - | - | - | - "
                  f"| {r.get('error', '?')[:40]} |")
            continue
        m = r["memory"]
        print(f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} | "
              f"{m['argument_bytes']/2**30:.2f} | "
              f"{m['temp_bytes']/2**30:.2f} | "
              f"{fmt(r.get('dot_flops'))} | {fmt(r.get('hbm_bytes'))} | "
              f"{fmt(r.get('collective_bytes_total'))} |")

    print("\n### Roofline (single-pod 16x16, per device)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL_FLOPs/dev | useful ratio | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if mesh != "16x16" or not r.get("ok"):
            continue
        cfg = CFG.get(arch)
        tc = r.get("dot_flops", 0) / PEAK
        tm = r.get("hbm_bytes", 0) / HBM
        tl = r.get("collective_bytes_total", 0) / LINK
        dom = max((("compute", tc), ("memory", tm), ("collective", tl)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops_per_device(cfg, shape)
        ratio = mf / r["dot_flops"] if r.get("dot_flops") else float("nan")
        note = ""
        if r.get("window_override"):
            note = f"SWA w={r['window_override']}"
        print(f"| {arch} | {shape} | {tc:.2e} | {tm:.2e} | {tl:.2e} | "
              f"{dom} | {fmt(mf)} | {ratio:.2f} | {note} |")

    stream_tables()


if __name__ == "__main__":
    main()
