"""Generate markdown tables from the machine-readable benchmark records:
the EXPERIMENTS.md dry-run table from experiments/dryrun/*.json, the
kernel-path comparison + per-kernel HLO roofline tables from
BENCH_kernels.json, and the streaming/hostile-network tables from
BENCH_stream.json.

The dry-run records are not checked in — generate them first with the
dry-run harness (its ``--out`` default is exactly the directory this
script reads):

    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
    PYTHONPATH=src python tools/gen_tables.py > experiments/tables.md

BENCH_kernels.json is produced by ``python -m benchmarks.kernels_bench``,
BENCH_stream.json by ``python -m benchmarks.anytime_stream``, and
BENCH_structure.json (edge-recovery + vote/communication tables) by
``python -m benchmarks.structure_bench``.
Records carrying an unknown ``schema_version`` are REJECTED loudly (exit
1) rather than rendered wrong: a version this reader does not know means
the payload layout changed after this script was written.
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src"); sys.path.insert(0, ".")


def fmt(x, unit=""):
    if x is None:
        return "-"
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(x) >= div:
            return f"{x/div:.2f}{suf}{unit}"
    return f"{x:.2f}{unit}"


#: BENCH_*.json schema versions this reader understands. 1 == the
#: pre-provenance payloads, which carried no version stamp at all.
KNOWN_SCHEMA_VERSIONS = (1, 2)


def check_schema(payload: dict, path: str) -> None:
    """Refuse to render a BENCH record whose schema this script predates."""
    version = payload.get("schema_version", 1)
    if version not in KNOWN_SCHEMA_VERSIONS:
        sys.exit(
            f"{path}: schema_version {version!r} is unknown to this "
            f"reader (understands {list(KNOWN_SCHEMA_VERSIONS)}); "
            f"regenerate the record or update tools/gen_tables.py")


def _prov_line(payload: dict) -> None:
    prov = payload.get("provenance")
    if prov:
        mode = prov.get("kernel_path", prov.get("kernel_mode", "?"))
        print(f"_{prov.get('backend', '?')}/{mode}, "
              f"{prov.get('git_sha', 'unknown')[:12]}, "
              f"{prov.get('timestamp', '?')}_\n")


def kernel_tables():
    """Render BENCH_kernels.json: the per-path comparison rows (ref /
    compiled / interpret, with measured speedups and tuned tiles) and the
    per-kernel HLO roofline columns that superseded benchmarks.roofline."""
    path = "BENCH_kernels.json"
    print("\n### Kernel path comparison (BENCH_kernels.json)\n")
    if not os.path.exists(path):
        print("(no record — run `PYTHONPATH=src python -m "
              "benchmarks.kernels_bench`)")
        return
    payload = json.load(open(path))
    check_schema(payload, path)
    _prov_line(payload)
    print("| op | shape | ref us | compiled us | speedup | path | tiles | "
          "interpret us | max err |")
    print("|---|---|---|---|---|---|---|---|---|")
    rows = []
    for fam, rec in sorted(payload.get("families", {}).items()):
        r = rec.get("rows", {})
        comp = r.get("compiled", {})
        rows.append((f"score/{fam}", rec.get("shape", "?"), r.get("ref", {}),
                     comp, r.get("interpret", {}).get("us")))
    for kind, rec in sorted(payload.get("newton", {}).items()):
        comp = {"us": rec.get("compiled_us"),
                "speedup_vs_ref": rec.get("speedup_vs_ref"),
                "kernel_path": rec.get("kernel_path"),
                "max_err": rec.get("max_err"), "tiles": rec.get("tiles"),
                "hlo": rec.get("hlo")}
        rows.append((f"newton/{kind}", rec.get("shape", "?"),
                     {"us": rec.get("ref_us")}, comp, None))
    def cell(v, spec):
        return format(v, spec) if v is not None else "-"

    for op, shape, ref, comp, us_int in rows:
        tiles = comp.get("tiles") or {}
        tdesc = ",".join(f"{k}={v}" for k, v in sorted(tiles.items())
                         if v is not None) or "-"
        speedup = comp.get("speedup_vs_ref")
        print(f"| {op} | {shape} | {cell(ref.get('us'), '.0f')} | "
              f"{cell(comp.get('us'), '.0f')} | "
              f"{cell(speedup, '.2f')}{'x' if speedup is not None else ''} | "
              f"{comp.get('kernel_path', '?')} | {tdesc} | "
              f"{cell(us_int, '.0f')} | "
              f"{cell(comp.get('max_err'), '.1e')} |")

    print("\n### Kernel roofline (HLO dot FLOPs / HBM bytes, "
          "loop-corrected)\n")
    print("| op | dot FLOPs | HBM bytes | FLOP/byte |")
    print("|---|---|---|---|")
    for op, shape, ref, comp, us_int in rows:
        hlo = comp.get("hlo") or {}
        if "error" in hlo or not hlo:
            print(f"| {op} | - | - | {hlo.get('error', 'n/a')} |")
            continue
        print(f"| {op} | {fmt(hlo.get('dot_flops'))} | "
              f"{fmt(hlo.get('hbm_bytes'))} | "
              f"{cell(hlo.get('flop_per_byte'), '.3f')} |")


def stream_tables():
    """Render BENCH_stream.json: per-graph any-time rows plus the PR 6
    hostile-network section (Byzantine robustness, drift tracking,
    crash/restart, durable restore)."""
    path = "BENCH_stream.json"
    print("\n### Streaming any-time trajectories (BENCH_stream.json)\n")
    if not os.path.exists(path):
        print("(no record — run `python -m benchmarks.anytime_stream`)")
        return
    payload = json.load(open(path))
    check_schema(payload, path)
    _prov_line(payload)
    print("| graph | method | err first | err last | samples/node | "
          "scalars sent |")
    print("|---|---|---|---|---|---|")
    for gname, rec in sorted(payload.get("graphs", {}).items()):
        for meth, tr in sorted(rec.get("methods", {}).items()):
            err = tr["err"]
            print(f"| {gname} | {meth} | {err[0]:.4f} | {err[-1]:.4f} | "
                  f"{tr['samples_seen'][-1]:.0f} | "
                  f"{tr['scalars_sent'][-1]} |")

    hostile = payload.get("hostile")
    if not hostile:
        return
    print("\n### Hostile network (star10, 20% Byzantine)\n")
    print("| scenario | fault-free err | hostile err | note |")
    print("|---|---|---|---|")
    meths = hostile.get("methods", {})
    for scheme in ("uniform", "trimmed_mean", "krum"):
        row = meths.get(f"byzantine_{scheme}")
        if row is None:
            continue
        ratio = row["err_hostile"] / max(row["err_fault_free"], 1e-12)
        print(f"| byzantine sign-flip / {scheme} | "
              f"{row['err_fault_free']:.4f} | {row['err_hostile']:.4f} | "
              f"{ratio:.1f}x fault-free |")
    if "drift" in meths:
        d = meths["drift"]
        print(f"| change-point drift | {d['err_plain']:.4f} (plain) | "
              f"{d['err_windowed']:.4f} (windowed) | windowed re-fit "
              f"tracks |")
    if "crash_restart" in meths:
        err = meths["crash_restart"]["err"]
        print(f"| crash/restart | {err[0]:.4f} | {err[-1]:.4f} | "
              f"survivors keep converging |")
    if "kill_restore" in meths:
        md = meths["kill_restore"]["restore_maxdiff"]
        print(f"| kill + durable restore | - | {md:.1e} | max traj diff "
              f"vs uninterrupted |")
    tel = hostile.get("telemetry")
    if tel:
        print(f"| telemetry replay | - | - | {tel['events']} events, "
              f"{tel['fault_injections']} faults fired, "
              f"{tel['robust_rejections']} robust rejections, "
              f"replayed scalars {tel['scalars_sent_replayed']} |")


def structure_tables():
    """Render BENCH_structure.json: planted-graph edge recovery (cold /
    warm / vs sample size, with the path compile invariant columns) and
    the F1-vs-communication-budget sweep from knn screening."""
    path = "BENCH_structure.json"
    print("\n### Structure learning: planted-graph edge recovery "
          "(BENCH_structure.json)\n")
    if not os.path.exists(path):
        print("(no record — run `PYTHONPATH=src python -m "
              "benchmarks.structure_bench`)")
        return
    payload = json.load(open(path))
    check_schema(payload, path)
    _prov_line(payload)
    cfg = payload.get("config", {})
    print(f"_planted {cfg.get('graph', '?')} (p={cfg.get('p', '?')}, "
          f"{cfg.get('m_true', '?')} true edges), "
          f"n={cfg.get('n_accept', '?')}, "
          f"F1 floor {cfg.get('f1_floor', '?')}_\n")
    print("| family | run | F1 | precision | recall | support | "
          "path compiles | new compiles | wall s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for fam, rec in sorted(payload.get("families", {}).items()):
        acc = rec.get("accept", {})
        for run in ("cold", "warm"):
            r = acc.get(run)
            if r is None:
                continue
            print(f"| {fam} | {run} | {r['f1']:.3f} | "
                  f"{r['precision']:.3f} | {r['recall']:.3f} | "
                  f"{r['support_size']} | {r['path_compiles']} | "
                  f"{r['new_compiles']} | {r['wall_s']:.1f} |")

    print("\n### Structure learning: F1 vs sample size\n")
    print("| family | n | F1 | precision | recall | support |")
    print("|---|---|---|---|---|---|")
    for fam, rec in sorted(payload.get("families", {}).items()):
        for r in rec.get("f1_vs_n", []):
            print(f"| {fam} | {r['n']} | {r['f1']:.3f} | "
                  f"{r['precision']:.3f} | {r['recall']:.3f} | "
                  f"{r['support_size']} |")

    comm = payload.get("f1_vs_comm", {})
    if comm:
        print("\n### Structure learning: F1 vs communication budget "
              "(knn screening)\n")
        print("| family | knn k | candidates | vote scalars | F1 | "
              "precision | recall |")
        print("|---|---|---|---|---|---|---|")
        for fam, rows in sorted(comm.items()):
            for r in rows:
                k = r.get("knn_k")
                print(f"| {fam} | {'full' if k is None else k} | "
                      f"{r['candidates']} | {r['comm_scalars']} | "
                      f"{r['f1']:.3f} | {r['precision']:.3f} | "
                      f"{r['recall']:.3f} |")


def main():
    recs = {}
    paths = sorted(glob.glob("experiments/dryrun/*.json"))
    if not paths:
        print("no dry-run records found under experiments/dryrun/ — "
              "generate them first:\n"
              "    PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--out experiments/dryrun", file=sys.stderr)
        print("### Dry-run\n\n(no records)")
        kernel_tables()
        stream_tables()
        structure_tables()
        return
    for path in paths:
        r = json.load(open(path))
        if "arch" in r:
            recs[(r["arch"], r["shape"], r["mesh"])] = r

    print("### Dry-run (all 40 combos x 2 meshes)\n")
    print("| arch | shape | mesh | ok | compile_s | args GiB/dev | "
          "temp GiB/dev | dot FLOPs/dev | HBM B/dev | coll B/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if not r.get("ok"):
            print(f"| {arch} | {shape} | {mesh} | FAIL | - | - | - | - | - "
                  f"| {r.get('error', '?')[:40]} |")
            continue
        m = r["memory"]
        print(f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} | "
              f"{m['argument_bytes']/2**30:.2f} | "
              f"{m['temp_bytes']/2**30:.2f} | "
              f"{fmt(r.get('dot_flops'))} | {fmt(r.get('hbm_bytes'))} | "
              f"{fmt(r.get('collective_bytes_total'))} |")

    kernel_tables()
    stream_tables()
    structure_tables()


if __name__ == "__main__":
    main()
