"""Regenerate the golden-value regression fixture for the estimator stack.

Freezes seeded outputs of ``fit_all_local_batched`` (per-node local thetas)
and ``consensus.combine`` (all four one-step weighting schemes) on a small
grid-graph Ising problem into ``tests/core/golden_estimators.json``;
``tests/core/test_golden.py`` asserts future runs reproduce them to 1e-10,
catching silent numeric drift in refactors of the batched engine, the
Gauss-Jordan solver, or the vectorized combiner.

Run from the repo root after an *intentional* numeric change:

    PYTHONPATH=src python tools/gen_golden.py
"""
import json
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)

import numpy as np  # noqa: E402

import repro.core as C  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "tests", "core", "golden_estimators.json")

#: the frozen scenario — change it only together with the fixture
CONFIG = {"graph": "grid_graph(2, 3)", "model_key": 11, "sample_key": 12,
          "sigma_pair": 0.5, "sigma_single": 0.3, "n": 400,
          "schemes": ["uniform", "diagonal", "optimal", "max"]}


def compute():
    g = C.grid_graph(2, 3)
    m = C.random_model(g, CONFIG["sigma_pair"], CONFIG["sigma_single"],
                       jax.random.PRNGKey(CONFIG["model_key"]))
    X = C.exact_sample(m, CONFIG["n"],
                       jax.random.PRNGKey(CONFIG["sample_key"]))
    fits = C.fit_all_local(g, X, method="batched")
    payload = {
        "config": CONFIG,
        "theta_star": np.asarray(m.theta, dtype=np.float64).tolist(),
        "local_theta": [np.asarray(f.theta, dtype=np.float64).tolist()
                        for f in fits],
        "local_vdiag": [np.diag(f.V).astype(np.float64).tolist()
                        for f in fits],
        "combine": {
            sch: C.combine(g, fits, sch).astype(np.float64).tolist()
            for sch in CONFIG["schemes"]
        },
    }
    return payload


def main():
    payload = compute()
    with open(OUT, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
